"""Discrete-event task-graph runtime (repro.sim) vs the analytical model,
the JAX lowering, and the paper's two quantitative claims."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
from repro.core.fusion import Epilogue, EpilogueOperands, cute_matmul
from repro.core.hardware import BOOM, KUNMINGHU, PLATFORMS, ROCKET, SHUTTLE
from repro.core.simulator import LayerTrace, simulate_gemm, simulate_layer
from repro.core.task import BiasType, MatMulTask
from repro.sim.desim import simulate_graph
from repro.sim.graph import Granularity, TaskGraph, build_gemm_graph
from repro.sim.lower import (desim_gemm, desim_layer, desim_workload,
                             epilogue_vector_ops, execute_graph_jax,
                             exposed_dispatch, layer_to_graph,
                             workload_to_graph)
from repro.sim.trace import chrome_trace, dump_chrome_trace


# ---------------------------------------------------------------------------
# TaskGraph IR.
# ---------------------------------------------------------------------------

class TestTaskGraph:
    def test_tile_count_and_program_order(self):
        task = MatMulTask(m=130, n=70, k=64)
        graph, sinks = build_gemm_graph(task, 64, 64)
        assert len(graph.matmul_nodes()) == 3 * 2    # ceil(130/64)*ceil(70/64)
        order = [n.nid for n in graph.topo_order()]
        assert order == sorted(order)
        # edge tiles keep true extents
        assert graph.matmul_nodes()[-1].task.m == 2
        assert graph.matmul_nodes()[-1].task.n == 6

    def test_granularity_vector_node_counts(self):
        task = MatMulTask(m=256, n=128, k=64)
        for gran, expect in [(Granularity.TILE, 8), (Granularity.PANEL, 4),
                             (Granularity.LAYER, 1)]:
            g, vecs = build_gemm_graph(task, 64, 64, granularity=gran,
                                       vector_ops={"relu": 256 * 128})
            assert len(g.vector_nodes()) == expect
            # abstract cost is conserved across the split
            total = sum(v.vector_ops["relu"] for v in g.vector_nodes())
            assert total == pytest.approx(256 * 128)

    def test_forward_dep_rejected(self):
        g = TaskGraph()
        with pytest.raises(ValueError):
            g.add("vector", "bad", deps=(0,))        # node 0 doesn't exist

    def test_sinks(self):
        task = MatMulTask(m=128, n=128, k=64)
        g, vecs = build_gemm_graph(task, 64, 64, granularity=Granularity.LAYER,
                                   vector_ops={"relu": 1.0})
        assert [s.nid for s in g.sinks()] == [v.nid for v in vecs]


# ---------------------------------------------------------------------------
# DESim vs the analytical closed form.
# ---------------------------------------------------------------------------

def _layer(k=2048, vec_elems=512 * 512):
    return LayerTrace(
        name="linear+silu",
        gemms=(MatMulTask(m=512, n=512, k=k),),
        vector_ops={"silu": vec_elems, "quant": vec_elems},
        intermediate_bytes=vec_elems * 4.0)


class TestDesimVsAnalytic:
    @pytest.mark.parametrize("fused", [True, False])
    def test_layer_within_15pct(self, fused):
        layer = _layer()
        d = desim_layer(CASE_STUDY, layer, fused=fused)
        a = simulate_layer(CASE_STUDY, layer, fused=fused)
        assert d["cycles"] == pytest.approx(a["cycles"], rel=0.15)

    def test_gemm_within_15pct_both_regimes(self):
        for k in (256, 8192):                        # memory- / compute-bound
            t = MatMulTask(m=512, n=512, k=k)
            d = desim_gemm(PLATFORM_2TOPS, t, SHUTTLE)
            a = simulate_gemm(PLATFORM_2TOPS, t, SHUTTLE)
            assert d.cycles == pytest.approx(a.cycles, rel=0.15), k

    def test_panel_granularity_mixed_gemm_widths(self):
        """PANEL groups are per-GEMM rows even when GEMM widths differ."""
        layer = LayerTrace(
            "mixed", gemms=(MatMulTask(m=128, n=128, k=256),
                            MatMulTask(m=128, n=512, k=256)),
            vector_ops={"relu": 128 * 640})
        graph, vecs = layer_to_graph(CASE_STUDY, layer, fused=True,
                                     granularity=Granularity.PANEL)
        # 2 rows in each GEMM: 128/64 = 2 panels + 2 panels.
        assert len(vecs) == 4
        for v in vecs:
            rows = {graph.nodes[d].tile.m0 for d in v.deps}
            gemm = {graph.nodes[d].layer for d in v.deps}
            assert len(rows) == 1 and len(gemm) == 1   # no straddling

    def test_fused_beats_unfused_and_bounds(self):
        layer = _layer()
        f = desim_layer(CASE_STUDY, layer, fused=True)
        u = desim_layer(CASE_STUDY, layer, fused=False)
        assert f["cycles"] < u["cycles"]
        # fused makespan can't beat either stream alone
        assert f["cycles"] >= max(f["matrix"], f["vector"])

    def test_workload_chaining(self):
        """A chained two-layer graph serialises layers: its makespan is at
        least either layer alone and about their sum."""
        layers = [_layer(k=512, vec_elems=64 * 64), _layer(k=1024,
                                                           vec_elems=64 * 64)]
        g = workload_to_graph(CASE_STUDY, layers)
        r = simulate_graph(g, CASE_STUDY, SHUTTLE)
        parts = [desim_layer(CASE_STUDY, l)["cycles"] for l in layers]
        assert r.cycles >= max(parts)
        assert r.cycles == pytest.approx(sum(parts), rel=0.15)
        # expand_repeat instantiates the copies
        rep = LayerTrace("r", layers[0].gemms, layers[0].vector_ops,
                         layers[0].intermediate_bytes, repeat=3)
        g1 = workload_to_graph(CASE_STUDY, [rep])
        g3 = workload_to_graph(CASE_STUDY, [rep], expand_repeat=True)
        assert len(g3) == 3 * len(g1)
        r3 = simulate_graph(g3, CASE_STUDY, SHUTTLE)
        assert r3.cycles == pytest.approx(
            desim_layer(CASE_STUDY, rep)["cycles"], rel=0.15)


# ---------------------------------------------------------------------------
# Paper claim 1: ≥90% matrix-unit utilization, large int8 GEMM, 4 platforms.
# ---------------------------------------------------------------------------

class TestUtilizationClaim:
    def test_fig6_90pct_all_platforms(self):
        t = MatMulTask(m=512, n=512, k=8192)
        for name, platform in PLATFORMS.items():
            r = desim_gemm(PLATFORM_2TOPS, t, platform)
            assert r.matrix_utilization > 0.90, (name, r.matrix_utilization)
            # PE-array busy fraction agrees with the Eq.1-based metric
            assert r.utilization("pe_array") > 0.90, name

    def test_resource_timelines_cover_makespan(self):
        r = desim_gemm(PLATFORM_2TOPS, MatMulTask(m=512, n=512, k=1024),
                       SHUTTLE)
        for name, ivals in r.intervals.items():
            if name != "vector_unit":        # bare GEMM: no epilogues
                assert ivals, f"{name} timeline empty"
            for s, e, _ in ivals:
                assert 0.0 <= s <= e <= r.cycles + 1e-9, name
        # banks are held for load+compute spans, so they're busier than
        # the PE alone but never beyond capacity.
        assert 0.0 < r.utilization("scratchpad") <= 1.0


# ---------------------------------------------------------------------------
# Paper claim 2: ≥30% overlap-attributed speedup on a Llama-style stack.
# ---------------------------------------------------------------------------

class TestOverlapClaim:
    def test_llama_stack_overlap_gain(self):
        from benchmarks.workloads import llama3_1b_layers
        layers = llama3_1b_layers(seq=1024)
        f = desim_workload(CASE_STUDY, layers, fused=True)
        u = desim_workload(CASE_STUDY, layers, fused=False)
        assert u["cycles"] / f["cycles"] >= 1.30


# ---------------------------------------------------------------------------
# Dispatch-queue backpressure: CSR (Kunminghu) vs RoCC platforms.
# ---------------------------------------------------------------------------

class TestDispatchBackpressure:
    def test_csr_exposes_more_dispatch_than_rocc(self):
        unit = PLATFORM_2TOPS.with_(m_scp=16, n_scp=16)   # tiny-tile stream
        t = MatMulTask(m=128, n=128, k=32)
        csr = exposed_dispatch(unit, t, KUNMINGHU)
        for rocc in (ROCKET, SHUTTLE, BOOM):
            assert csr > exposed_dispatch(unit, t, rocc) > 0.0

    def test_dispatcher_serialises_in_program_order(self):
        unit = PLATFORM_2TOPS.with_(m_scp=16, n_scp=16)
        g, _ = build_gemm_graph(MatMulTask(m=64, n=64, k=32), 16, 16)
        r = simulate_graph(g, unit, KUNMINGHU)
        disp = sorted((s, e) for s, e, lbl in r.intervals["dispatcher"]
                      if lbl.endswith("/disp"))
        assert len(disp) == 16
        for (s0, e0), (s1, e1) in zip(disp, disp[1:]):
            assert s1 >= e0 - 1e-9                   # no overlap: serial CPU


# ---------------------------------------------------------------------------
# The same graph lowered to JAX matches cute_matmul.
# ---------------------------------------------------------------------------

class TestJaxLowering:
    @pytest.mark.parametrize("gran", [Granularity.TILE, Granularity.PANEL,
                                      Granularity.LAYER])
    def test_epilogue_graph_matches_cute_matmul(self, gran):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        m, k, n = 96, 64, 80
        a = jax.random.normal(ks[0], (m, k), jnp.float32)
        b = jax.random.normal(ks[1], (k, n), jnp.float32)
        ep = Epilogue(bias_type=BiasType.ROW, activation="gelu",
                      has_residual=True)
        ops = EpilogueOperands(bias=jax.random.normal(ks[2], (n,)),
                               residual=jax.random.normal(ks[3], (m, n)))
        task = MatMulTask(m=m, n=n, k=k, data_type="fp32")
        graph, _ = build_gemm_graph(task, 32, 32, granularity=gran,
                                    vector_ops=epilogue_vector_ops(ep, m, n),
                                    epilogue=ep)
        out = execute_graph_jax(graph, a, b, operands=ops)
        ref = cute_matmul(a, b, epilogue=ep, operands=ops)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_accumulators_exact(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        a = jax.random.randint(ks[0], (64, 128), -8, 8, jnp.int8)
        b = jax.random.randint(ks[1], (128, 64), -8, 8, jnp.int8)
        graph, _ = build_gemm_graph(MatMulTask(m=64, n=64, k=128), 32, 32)
        out = execute_graph_jax(graph, a, b)
        ref = cute_matmul(a, b)
        assert out.dtype == ref.dtype == jnp.int32
        assert bool(jnp.all(out == ref))

    def test_glu_panel_granularity(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        a = jax.random.randint(ks[0], (64, 64), -4, 4, jnp.int8)
        b = jax.random.randint(ks[1], (64, 128), -4, 4, jnp.int8)
        ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.float32)
        graph, _ = build_gemm_graph(MatMulTask(m=64, n=128, k=64), 32, 32,
                                    granularity=Granularity.PANEL,
                                    epilogue=ep)
        out = execute_graph_jax(graph, a, b)
        ref = cute_matmul(a, b, epilogue=ep)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_multi_gemm_graph_rejected(self):
        layer = LayerTrace("two", gemms=(MatMulTask(m=64, n=64, k=64),
                                         MatMulTask(m=64, n=64, k=64)))
        graph, _ = layer_to_graph(CASE_STUDY, layer)
        a = jnp.zeros((64, 64), jnp.float32)
        with pytest.raises(ValueError, match="single-GEMM"):
            execute_graph_jax(graph, a, a)

    def test_glu_tile_granularity_rejected(self):
        ep = Epilogue(activation="silu", glu=True)
        graph, _ = build_gemm_graph(MatMulTask(m=64, n=128, k=64), 32, 32,
                                    granularity=Granularity.TILE, epilogue=ep)
        a = jnp.zeros((64, 64), jnp.float32)
        b = jnp.zeros((64, 128), jnp.float32)
        with pytest.raises(ValueError, match="full-N"):
            execute_graph_jax(graph, a, b)


# ---------------------------------------------------------------------------
# Stride-dependent DRAM efficiency (paper §5.4).
# ---------------------------------------------------------------------------

class TestStrideDramEfficiency:
    def test_derate_curve_pinned(self):
        from repro.sim.resources import (DRAM_JUMP_GAP_BYTES,
                                         DRAM_REFERENCE_RUN_BYTES,
                                         dram_stride_efficiency)
        base = 0.92
        # the reference 64-byte run reproduces the calibrated flat derate
        assert dram_stride_efficiency(64.0, base) == pytest.approx(base)
        # longer runs saturate there (dense == the old flat model)
        for run in (128.0, 4096.0, 1e7):
            assert dram_stride_efficiency(run, base) == pytest.approx(base)
        # sub-burst runs follow run/(run+gap) normalised at the reference
        ref = DRAM_REFERENCE_RUN_BYTES / (DRAM_REFERENCE_RUN_BYTES
                                          + DRAM_JUMP_GAP_BYTES)
        for run in (8.0, 16.0, 32.0, 48.0):
            expect = base * (run / (run + DRAM_JUMP_GAP_BYTES)) / ref
            assert dram_stride_efficiency(run, base) == pytest.approx(expect)
        assert dram_stride_efficiency(16.0, base) == pytest.approx(0.575)
        # monotone non-decreasing in run length
        effs = [dram_stride_efficiency(r, base)
                for r in (4, 8, 16, 32, 64, 128, 1024)]
        assert effs == sorted(effs)
        # degenerate run falls back to the flat derate
        assert dram_stride_efficiency(0.0, base) == base

    def test_contiguous_runs_from_task_strides(self):
        from repro.sim.resources import contiguous_run_bytes
        # dense rows merge into one run; strided views jump per row
        assert contiguous_run_bytes(64, 256, 256, 1.0) == 64 * 256
        assert contiguous_run_bytes(64, 256, 4096, 1.0) == 256
        assert contiguous_run_bytes(16, 16, 512, 2.0) == 32

    def test_strided_operands_slow_the_des(self):
        """A narrow column slice of a wide row-major B (stride_b ≫ n)
        streams sub-burst runs and measurably lengthens the makespan;
        dense tasks are untouched vs the flat-derate model."""
        unit = CASE_STUDY.with_(n_scp=16)
        dense = MatMulTask(m=256, n=16, k=1024)               # stride_b = n
        strided = MatMulTask(m=256, n=16, k=1024, stride_b=4096)
        rd = desim_gemm(unit, dense, SHUTTLE)
        rs = desim_gemm(unit, strided, SHUTTLE)
        assert rs.cycles > rd.cycles * 1.05
        # strided A with short K rows pays the same way
        short_dense = MatMulTask(m=256, n=64, k=32)
        short_strided = MatMulTask(m=256, n=64, k=32, stride_a=8192)
        ra_d = desim_gemm(CASE_STUDY, short_dense, SHUTTLE)
        ra_s = desim_gemm(CASE_STUDY, short_strided, SHUTTLE)
        assert ra_s.cycles > ra_d.cycles

    def test_tile_tasks_inherit_parent_strides(self):
        """Tiling a strided view keeps the stride, so the DES sees the
        paper's §5.4 access pattern at tile granularity."""
        from repro.core.task import tile_tasks
        parent = MatMulTask(m=128, n=32, k=64, stride_b=4096)
        for sub in tile_tasks(parent, 64, 16):
            assert sub.stride_b == 4096


# ---------------------------------------------------------------------------
# Chrome-trace export.
# ---------------------------------------------------------------------------

class TestTraceExport:
    def test_chrome_trace_valid_json(self, tmp_path):
        r = desim_gemm(CASE_STUDY, MatMulTask(m=256, n=256, k=512), SHUTTLE)
        path = dump_chrome_trace(r, str(tmp_path / "t.json"))
        data = json.loads(open(path).read())         # round-trips
        events = data["traceEvents"]
        assert events, "empty trace"
        xs = [e for e in events if e["ph"] == "X"]
        assert xs
        for e in xs:
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert {"name", "pid", "tid"} <= set(e)
        # every machine resource got a named row
        rows = {e["args"]["name"] for e in events
                if e["ph"] == "M" and e["name"] == "thread_name"}
        assert {"dispatcher", "mem_loader", "scratchpad", "pe_array",
                "vector_unit"} <= rows

    def test_fused_trace_interleaves_vector_with_pe(self):
        """The point of the subsystem: the trace *shows* the overlap."""
        layer = _layer()
        graph, _ = layer_to_graph(CASE_STUDY, layer, fused=True)
        r = simulate_graph(graph, CASE_STUDY, SHUTTLE)
        pe = r.intervals["pe_array"]
        vec = r.intervals["vector_unit"]
        pe_end = max(e for _, e, _ in pe)
        overlapped = sum(
            min(e, pe_end) - s for s, e, _ in vec if s < pe_end)
        assert overlapped > 0.5 * r.busy("vector_unit")

"""Grouped (per-expert) GEMM kernel for MoE layers.

Capacity-dispatched MoE turns the expert MLP into a batched ragged GEMM:
``x (E, Cap, K) @ w (E, K, N) -> (E, Cap, N)``.  On TPU the clean mapping
is a 4-D grid with the expert axis outermost — each expert's weight panel
is DMA'd once and reused across its capacity tiles, which is precisely
the paper's scratchpad-reuse argument (weights resident, activations
streamed).  Fused epilogue (bias/activation/GLU) matches the main
``cute_matmul`` kernel so MoE experts get the same matrix–vector overlap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fusion import Epilogue, EpilogueOperands, apply_epilogue


def grouped_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, ep: Epilogue,
                          n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0]
    if ep.glu:
        w = w.reshape(w.shape[0], -1)
    acc_ref[...] += jnp.dot(x_ref[0], w,
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = apply_epilogue(acc_ref[...], ep, EpilogueOperands())

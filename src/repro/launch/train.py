"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \\
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/run1

Production shape: config → mesh → sharded state → fault-tolerant loop
(async checkpoints, straggler watchdog, preemption handler, auto-resume).
On this CPU host the mesh is whatever ``jax.device_count()`` provides;
on a real cluster the same flags drive the 16×16 / 2×16×16 meshes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import logical, sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.base import family_module
from repro.optim import adamw
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.watchdog import PreemptionHandler, StepWatchdog
from repro.training.train_step import TrainConfig, make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="yi-6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("host", "single", "multi"),
                    default="host")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.with_(dtype=jnp.float32, remat="none")
    mod = family_module(cfg)

    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                    warmup_steps=max(args.steps // 20, 1)),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        loss_chunk=min(512, args.seq_len))
    step_fn = make_train_step(cfg, tcfg)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  global_batch=args.global_batch,
                                  seq_len=args.seq_len))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = StepWatchdog()
    preempt = PreemptionHandler()

    with logical.use_rules(mesh, None):
        params = mod.init(cfg, jax.random.PRNGKey(0))
        pshard = sharding.param_shardings(params, mesh)
        params = sharding.apply_shardings(params, pshard)
        opt = adamw.init(tcfg.optimizer, params)
        residual = None
        start = 0
        if mgr and mgr.latest_step() is not None:
            restored, extra = mgr.restore(mgr.latest_step(),
                                          {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            data.load_state_dict(extra["data"])
            start = extra["train_step"]
            print(f"resumed from step {start}")

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = next(data)
            params, opt, metrics, residual = jit_step(params, opt, batch,
                                                      residual)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = watchdog.record_step(dt)
            if step % args.log_every == 0 or slow:
                tag = " STRAGGLER" if slow else ""
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f}ms{tag}", flush=True)
            want_ckpt = mgr and ((step + 1) % args.ckpt_every == 0
                                 or preempt.requested)
            if want_ckpt:
                mgr.save_async(step + 1, {"params": params, "opt": opt},
                               extra={"data": data.state_dict(),
                                      "train_step": step + 1})
            if preempt.requested:
                print("preemption requested: checkpointed, exiting")
                break
        if mgr:
            mgr.wait()
    watchdog.close()
    print(f"done: {watchdog.steps} steps, "
          f"{watchdog.straggler_events} straggler events")
    return params


if __name__ == "__main__":
    main()

"""The unified execution contract: one ``asyncMatMul``, four engines.

The paper's central software claim is that a single asynchronous matmul
abstraction "conceals hardware details … and supports a unified software
stack" across four CPU platforms.  :class:`Backend` is that abstraction
for this repository: every engine — eager JAX, the Pallas fused kernel,
the discrete-event machine model, the closed-form analytical model —
implements the same four verbs with the paper's vocabulary:

* ``dispatch(task, operands) -> DispatchHandle`` — ``asyncMatMul``:
  fire one :class:`~repro.core.task.MatMulTask` and return immediately.
  The task's ``Status`` interface register moves ``IDLE -> RUNNING``.
* ``check(handle)`` — ``checkMatmul`` as a non-blocking poll of the
  Status register.
* ``wait(handle) -> ExecResult`` — force completion; the Status register
  moves to ``DONE``.  Executing backends return numbers, modelling
  backends return cycles/timelines, the desim backend returns both.
* ``run_graph(graph, operands)`` — run a whole
  :class:`~repro.sim.graph.TaskGraph` (the tiled, dependency-linked form
  one logical matmul or a serving schedule lowers to).

Granularity (``tile | panel | layer``) and epilogue fusion are
first-class: every backend is constructed with a
:class:`~repro.sim.graph.Granularity` and a ``fused`` flag, and
``lower()`` applies them when tiling work into a TaskGraph — so the same
``MatMulTask`` travels the whole stack unchanged and only the engine
underneath differs.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Optional, Union

from repro.core.config import CASE_STUDY, MatrixUnitConfig
from repro.core.fusion import (Epilogue, EpilogueOperands, NO_EPILOGUE,
                               NO_OPERANDS)
from repro.core.hardware import CpuPlatform, SHUTTLE
from repro.core.simulator import LayerTrace, SATURN_512, VectorUnit
from repro.core.task import MatMulTask, Status


@dataclasses.dataclass(frozen=True)
class MatMulOperands:
    """Concrete arrays for one ``asyncMatMul``.

    ``a``/``b`` are the matrix operands (symbolic — i.e. absent — under
    the modelling backends, which read only the task descriptor);
    ``epilogue`` carries the vector-side arrays (bias, dequant scales,
    residual) the fused epilogue consumes.
    """

    a: object = None                       # (..., M, K) array
    b: object = None                       # (K, N) array
    epilogue: EpilogueOperands = NO_OPERANDS

    @property
    def concrete(self) -> bool:
        return self.a is not None and self.b is not None


NO_MATMUL_OPERANDS = MatMulOperands()

#: ``run_graph`` operands: one (a, b[, epilogue ops]) for a single-GEMM
#: graph, or {gemm label -> (a, b)} for a multi-GEMM schedule graph.
GraphOperands = Union[MatMulOperands, "dict[str, tuple]", None]


@dataclasses.dataclass
class ExecResult:
    """What ``wait``/``run_graph`` returns, across all backends.

    Executing backends fill ``output``/``outputs``; modelling backends
    fill ``cycles``/``seconds``/``utilization`` (+ ``timeline`` for the
    DES).  The desim backend fills both when given concrete operands.
    """

    output: object = None                  # single-GEMM numeric result
    outputs: "dict[str, object] | None" = None   # per-GEMM results (schedules)
    cycles: Optional[float] = None         # modelled makespan
    seconds: Optional[float] = None
    utilization: Optional[float] = None    # matrix-unit utilization
    timeline: object = None                # sim.desim.DESimResult
    detail: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class DispatchHandle:
    """The ``Status`` interface register, reified for any backend.

    ``done()`` reads the task's Status register — the same word
    ``checkMatmul`` polls in hardware — so a handle and its task can
    never disagree about completion.
    """

    task: MatMulTask
    _thunk: Callable[[], ExecResult]
    _result: Optional[ExecResult] = None

    def done(self) -> bool:
        return self.task.status is Status.DONE

    def force(self) -> ExecResult:
        if self._result is None:
            self._result = self._thunk()
            self.task.status = Status.DONE
        return self._result


class Backend(abc.ABC):
    """One execution engine behind the asyncMatMul contract."""

    name: str = "abstract"
    #: produces numeric outputs (JAX arrays)
    executes: bool = False
    #: produces cycle estimates / timelines
    models_time: bool = False
    #: understands ``units > 1`` (cluster backends); single-unit engines
    #: reject it rather than silently mispricing a multi-unit deployment.
    supports_units: bool = False

    def __init__(self, unit: MatrixUnitConfig = CASE_STUDY,
                 platform: CpuPlatform = SHUTTLE,
                 vector: VectorUnit = SATURN_512,
                 granularity=None, fused: bool = True, units: int = 1):
        from repro.sim.graph import Granularity
        if units != 1 and not self.supports_units:
            raise ValueError(
                f"backend {self.name!r} models a single matrix unit; for "
                f"units={units} use 'desim-cluster' (timelines) or "
                "'sharded' (execution)")
        self.unit = unit
        self.platform = platform
        self.vector = vector
        self.units = units
        self.granularity = Granularity(granularity or Granularity.TILE)
        self.fused = fused
        self.dispatched: "list[DispatchHandle]" = []

    # ----- asyncMatMul / checkMatmul ---------------------------------------
    def dispatch(self, task: MatMulTask,
                 operands: Optional[MatMulOperands] = None, *,
                 epilogue: Epilogue = NO_EPILOGUE) -> DispatchHandle:
        """Fire one task; returns immediately with a handle."""
        operands = operands or NO_MATMUL_OPERANDS
        thunk = self._stage(task, operands, epilogue)
        task.status = Status.RUNNING
        handle = DispatchHandle(task, thunk)
        self.dispatched.append(handle)
        return handle

    @abc.abstractmethod
    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        """Validate eagerly, compute lazily: return the forcing thunk."""

    def check(self, handle: DispatchHandle) -> bool:
        """Non-blocking ``checkMatmul`` poll."""
        return handle.done()

    def wait(self, handle: DispatchHandle) -> ExecResult:
        return handle.force()

    def drain(self) -> "list[ExecResult]":
        """Force every outstanding handle, oldest first, and forget them."""
        out = [h.force() for h in self.dispatched]
        self.dispatched.clear()
        return out

    # ----- granularity-aware lowering --------------------------------------
    def lower(self, work, *,
              epilogue: Optional[Epilogue] = None,
              vector_ops: "dict[str, float] | None" = None):
        """Tile ``work`` into a TaskGraph at this backend's granularity.

        :param work: one of

            * a single :class:`~repro.core.task.MatMulTask` — tiled by
              ``build_gemm_graph``; an optional fused ``epilogue`` has
              its abstract Saturn cost attached so the same graph
              carries both the simulation and the JAX payload;
            * a list of :class:`~repro.core.simulator.LayerTrace`\\ s —
              a workload, chained serially with this backend's
              ``fused`` policy via ``workload_to_graph``;
            * a serving ``BatchSchedule`` — lowered via
              ``schedule_to_graph`` with the schedule's own ``overlap``
              mode (``"relaxed"`` keeps only true per-request hazard
              edges) and its arrival-derived release times stamped on
              the nodes.
        :param epilogue: fused epilogue for the single-task form only.
        :param vector_ops: explicit abstract vector costs (single-task
            form only; derived from ``epilogue`` when omitted).
        :returns: a :class:`~repro.sim.graph.TaskGraph` ready for
            ``run_graph``.
        """
        from repro.sim.lower import (epilogue_vector_ops,
                                     schedule_to_graph, workload_to_graph)
        from repro.sim.graph import build_gemm_graph
        if isinstance(work, MatMulTask):
            if epilogue is not None and vector_ops is None:
                vector_ops = epilogue_vector_ops(epilogue, work.m, work.n)
            graph, _ = build_gemm_graph(
                work, self.unit.m_scp, self.unit.n_scp,
                granularity=self.granularity, vector_ops=vector_ops,
                epilogue=epilogue)
            return graph
        if epilogue is not None or vector_ops is not None:
            raise ValueError(
                "epilogue/vector_ops apply to a single MatMulTask; a "
                "LayerTrace workload carries its own vector work")
        if hasattr(work, "steps") and hasattr(work, "layers"):
            return schedule_to_graph(self.unit, work, fused=self.fused,
                                     granularity=self.granularity,
                                     platform=self.platform)
        return workload_to_graph(self.unit, list(work), fused=self.fused,
                                 granularity=self.granularity,
                                 platform=self.platform)

    # ----- whole-graph / whole-workload entry points -----------------------
    @abc.abstractmethod
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        """Run a TaskGraph end to end."""

    def run_workload(self, layers: "list[LayerTrace]", *,
                     fused: Optional[bool] = None,
                     unit: Optional[MatrixUnitConfig] = None,
                     platform: Optional[CpuPlatform] = None,
                     vector: Optional[VectorUnit] = None) -> "dict[str, float]":
        """Model-level cost of a LayerTrace workload (modelling backends
        only); same dict shape as ``core.simulator.simulate_workload``."""
        raise NotImplementedError(
            f"backend {self.name!r} executes numbers but has no workload "
            "cost model; use backend.get('desim') or "
            "backend.get('analytical')")

    def __repr__(self) -> str:
        return (f"<{type(self).__name__} {self.name!r} "
                f"granularity={self.granularity.value} fused={self.fused}>")

"""Architecture registry + the assigned shape grid + input_specs().

``input_specs(cfg, shape, mode)`` returns ShapeDtypeStruct stand-ins for
every model input — weak-type-correct, shardable, no device allocation —
consumed by the dry-run and the roofline benchmarks.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig

ARCH_MODULES = {
    "gemma2-2b": "repro.configs.gemma2_2b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "yi-6b": "repro.configs.yi_6b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ALL_ARCHS = tuple(ARCH_MODULES)


def get_config(name: str, reduced: bool = False, **overrides) -> ArchConfig:
    mod = importlib.import_module(ARCH_MODULES[name])
    cfg = mod.reduced() if reduced else mod.CONFIG
    return cfg.with_(**overrides) if overrides else cfg


# ---------------------------------------------------------------------------
# Assigned shapes (LM shapes are seq_len × global_batch).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: bounded-state archs that run the long-context decode cell.
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "recurrentgemma-2b")


def cell_applicable(arch: str, shape: str) -> bool:
    """Assignment rule: long_500k only for bounded-state archs."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def all_cells(include_skipped: bool = False):
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            if include_skipped or cell_applicable(arch, shape):
                yield arch, shape


# ---------------------------------------------------------------------------
# Abstract inputs.
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: "ShapeSpec | str",
                mode: "str | None" = None) -> dict:
    """Abstract batch for one (arch × shape) cell.

    train:   tokens + labels (B, S)         [+ stub frontend tensors]
    prefill: tokens (B, S)                  [+ stub frontend tensors]
    decode:  tokens (B, 1)                  (cache is built separately)
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    mode = mode or shape.mode
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if mode == "decode":
        specs["tokens"] = _sds((b, 1), jnp.int32)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
        if mode == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
    if cfg.vision_prefix and mode != "decode":
        specs["vision_embeds"] = _sds((b, cfg.vision_prefix, cfg.d_model),
                                      jnp.float32)
    if cfg.encdec is not None and mode != "decode":
        specs["audio_embeds"] = _sds((b, cfg.encdec.n_audio_ctx, cfg.d_model),
                                     jnp.float32)
    return specs


def concrete_batch(cfg: ArchConfig, batch_size: int, seq_len: int,
                   mode: str, key=None) -> dict:
    """Small concrete batch for smoke tests (mirrors input_specs)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    spec = ShapeSpec("smoke", seq_len, batch_size, mode)
    out = {}
    for name, s in input_specs(cfg, spec, mode).items():
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(ks[0], s.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(ks[1], s.shape, s.dtype)
    return out
